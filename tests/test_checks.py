"""``repro.checks`` test suite (ISSUE 7): every rule must fire on a seeded
defect and stay silent on a healthy artifact.

Structure mirrors the subsystem: Report currency, G-*/S-*/P-* structural
invariants (defects injected through the graph's private dicts or
``dataclasses.replace`` on frozen plans), E-FIFO over synthetic segment
journals, effect inference (scan-body scatters, annotations, opaque
fallback), hazard analysis (unordered scatter pairs, executor-placement
downgrade), the real paged decode × prefill-chunk cross-graph
certification, the W-ASSERT source scan, and the ``check=`` /
``Executable.verify()`` API integration.
"""
import copy
import dataclasses

import jax
import jax.numpy as jnp
import pytest

import repro
from repro.checks import (
    Report,
    check_graph,
    check_hazards,
    check_plan,
    check_schedule,
    check_segment_fifo,
    cross_graph_hazards,
    infer_effects,
    scan_asserts,
    segment_queues,
    shared_buffers,
    verify_all,
)
from repro.core import KNL7250, Graph, GraphValidationError, make_schedule
from repro.core.scheduler import Schedule
from repro.core.static_host import compile_host_plan, layered_graph
from repro.models import transformer
from repro.serve.step import make_paged_decode_step, make_prefill_chunk_step
from test_capture import TINY


def _setup(L=3, W=2, n_exec=2):
    g = layered_graph(L=L, W=W)
    sched = make_schedule(g, KNL7250, n_executors=n_exec, team_size=1)
    return g, sched, compile_host_plan(g, sched)


def _rules(rep):
    return set(rep.by_rule())


# ---------------------------------------------------------------------------
# Report currency
# ---------------------------------------------------------------------------

def test_report_currency():
    rep = Report()
    assert rep.ok and rep.summary().startswith("0 error")
    rep.add("X-A", "warning", "w msg")
    rep.add("X-B", "error", "e msg", node="n1")
    assert not rep.ok and len(rep.errors) == 1 and len(rep.warnings) == 1
    # render sorts most-severe first and includes rule ids
    body = rep.render()
    assert body.index("X-B") < body.index("X-A")
    with pytest.raises(GraphValidationError, match="X-B"):
        rep.raise_if_errors()
    with pytest.raises(ValueError):
        rep.add("X-C", "fatal", "not a severity")


def test_report_scoped_and_extend():
    inner = Report()
    inner.add("X-A", "info", "msg")
    outer = Report()
    outer.extend(inner.scoped("zone"))
    assert outer.findings[0].where == "zone"
    assert Report().render() == "clean: no findings"


# ---------------------------------------------------------------------------
# G-* graph invariants
# ---------------------------------------------------------------------------

def test_graph_clean():
    g, _, _ = _setup()
    assert check_graph(g).ok


def test_graph_cycle_flagged():
    g, _, _ = _setup()
    # Graph.add refuses forward deps, so a cycle can only enter through the
    # private dicts — exactly the tampered artifact the checker exists for
    name = g.names[1]
    node = g[name]
    g._nodes[name] = dataclasses.replace(node, deps=node.deps + (g.names[-1],))
    g._version += 1
    rep = check_graph(g)
    assert "G-CYCLE" in _rules(rep) and not rep.ok


def test_graph_self_and_unknown_dep():
    g, _, _ = _setup()
    name = g.names[2]
    g._nodes[name] = dataclasses.replace(g[name], deps=(name, "ghost"))
    g._version += 1
    rep = check_graph(g)
    msgs = [f.message for f in rep.errors if f.rule_id == "G-DEP"]
    assert any("itself" in m for m in msgs)
    assert any("ghost" in m for m in msgs)


# ---------------------------------------------------------------------------
# S-* schedule invariants
# ---------------------------------------------------------------------------

def test_schedule_clean():
    g, sched, _ = _setup()
    assert check_schedule(sched, g).ok


def test_schedule_dep_order_violation():
    g, sched, plan = _setup()
    pl = dict(sched.placements)
    late = plan.names[plan.programs[0][-1]]     # an op with executed deps
    e, _, _ = pl[late]
    pl[late] = (e, -1.0, -0.5)                  # starts before its deps end
    rep = check_schedule(dataclasses.replace(sched, placements=pl), g)
    assert "S-DEP" in _rules(rep)


def test_schedule_executor_out_of_range():
    g, sched, _ = _setup()
    pl = dict(sched.placements)
    n = next(iter(pl))
    _, s, t = pl[n]
    pl[n] = (99, s, t)
    rep = check_schedule(dataclasses.replace(sched, placements=pl), g)
    assert "S-EXEC" in _rules(rep)


def test_schedule_overlap():
    g, sched, _ = _setup()
    pl = dict(sched.placements)
    a, b = [k for k in pl if pl[k][2] > pl[k][1]][:2]
    pl[b] = pl[a]                               # same executor, same interval
    rep = check_schedule(dataclasses.replace(sched, placements=pl), g)
    assert "S-OVERLAP" in _rules(rep)


def test_schedule_coverage():
    g, sched, _ = _setup()
    pl = dict(sched.placements)
    pl.pop(next(iter(pl)))
    pl["phantom"] = (0, 0.0, 0.0)
    rep = check_schedule(dataclasses.replace(sched, placements=pl), g)
    msgs = [f.message for f in rep.errors if f.rule_id == "S-COVER"]
    assert any("missing" in m for m in msgs)
    assert any("not in graph" in m for m in msgs)


# ---------------------------------------------------------------------------
# P-* plan invariants
# ---------------------------------------------------------------------------

def test_plan_clean_and_verify_all():
    g, sched, plan = _setup()
    assert check_plan(plan, g).ok
    assert verify_all(g, sched, plan).ok


def test_plan_dropped_counter_deadlocks():
    g, _, plan = _setup()
    i = plan.programs[-1][-1]                   # an op that waits on deps
    n_wait = tuple(w + (1 if k == i else 0)
                   for k, w in enumerate(plan.n_wait))
    rep = check_plan(dataclasses.replace(plan, n_wait=n_wait), g)
    assert {"P-COUNTER", "P-REACH"} <= _rules(rep)
    assert any("deadlock" in f.message for f in rep.errors)


def test_plan_low_counter_races():
    g, _, plan = _setup()
    i = next(k for k in plan.programs[-1] if plan.n_wait[k] > 0)
    n_wait = tuple(w - (1 if k == i else 0)
                   for k, w in enumerate(plan.n_wait))
    rep = check_plan(dataclasses.replace(plan, n_wait=n_wait), g)
    assert "P-COUNTER" in _rules(rep)
    assert any("before its inputs exist" in f.message for f in rep.errors)


def test_plan_dropped_seed():
    g, _, plan = _setup()
    e = next(i for i, s in enumerate(plan.seeds) if s)
    seeds = tuple(s[1:] if i == e else s for i, s in enumerate(plan.seeds))
    rep = check_plan(dataclasses.replace(plan, seeds=seeds), g)
    assert {"P-SEED", "P-REACH"} <= _rules(rep)


def test_plan_program_order_violation():
    g, _, plan = _setup()
    progs = tuple(tuple(reversed(p)) for p in plan.programs)
    rep = check_plan(dataclasses.replace(plan, programs=progs), g)
    assert "P-TOPO" in _rules(rep)


def test_plan_owner_corruption():
    g, _, plan = _setup()
    owner = list(plan.owner)
    owner[plan.programs[0][0]] = 99
    rep = check_plan(dataclasses.replace(plan, owner=owner), g)
    assert {"P-COVER", "P-POISON"} <= _rules(rep)


def test_plan_stale_after_graph_mutation():
    g, _, plan = _setup()
    g.add_op("extra", deps=("out",), fn=lambda v: v)
    rep = check_plan(plan, g)
    assert _rules(rep) == {"P-STALE"}
    # the runtime enforces the same staleness contract at replay time
    with pytest.raises(GraphValidationError, match="mutated"):
        plan.run({"x": 1.0})


# ---------------------------------------------------------------------------
# E-FIFO segment journal
# ---------------------------------------------------------------------------

def test_fifo_cross_order_deadlock():
    rep = check_segment_fifo({0: [1, 2], 1: [2, 1]})
    assert "E-FIFO" in {f.rule_id for f in rep.errors}
    assert any("opposite orders" in f.message for f in rep.errors)


def test_fifo_duplicate_batch():
    rep = check_segment_fifo({0: [1, 1]})
    assert any("twice" in f.message for f in rep.errors)


def test_fifo_consistent_is_info_only():
    log = [(0, 1, "s0"), (1, 1, "s1"), (0, 2, "s2"), (1, 2, "s3")]
    rep = check_segment_fifo(segment_queues(log))
    assert rep.ok
    assert any(f.severity == "info" for f in rep.findings)


# ---------------------------------------------------------------------------
# effect inference
# ---------------------------------------------------------------------------

def test_effects_scan_body_scatter_seen():
    # the paged decode shape: a scatter hidden inside a lax.scan body must
    # still mark the pool input as written
    def fn(pool, xs):
        def body(p, x):
            p = p.at[0].set(x)
            return p, x * 2.0
        pool, ys = jax.lax.scan(body, pool, xs)
        return pool.sum() + ys.sum()

    pool = jnp.zeros((4, 8), jnp.float32)
    xs = jnp.ones((4, 8), jnp.float32)
    exe = repro.compile(fn, pool, xs)
    eff = infer_effects(exe.graph)
    bind = exe.captured.bind((pool, xs))
    pool_buf = next(n for n, v in bind.items() if v is pool)
    assert pool_buf in eff.written()
    assert eff.writers(pool_buf)


def test_effects_annotated_and_opaque():
    g = Graph("hand")
    g.add_op("buf", kind="input")
    g.add_op("w", deps=("buf",), fn=lambda b: b,
             meta={"effects": {"reads": ["buf"], "writes": ["buf"],
                               "carries": ["buf"]}})
    g.add_op("r", deps=("w",), fn=lambda b: b)      # no meta: opaque reader
    eff = infer_effects(g)
    assert eff.effects["w"].source == "annotated"
    assert eff.effects["w"].writes == {"buf"}
    assert eff.effects["r"].source == "opaque"
    assert eff.effects["r"].reads == {"buf"}        # carried through 'w'
    assert eff.read_only(["buf"]) is False


def test_shared_buffers_by_identity():
    x = jnp.zeros((2,))
    y = jnp.ones((3,))
    pairs = shared_buffers({"a": x, "b": y, "k": 3},
                           {"c": x, "d": jnp.zeros((2,)), "k2": 3})
    assert pairs == [("a", "c")]


# ---------------------------------------------------------------------------
# hazard analysis
# ---------------------------------------------------------------------------

def _two_writer_graph():
    g = Graph("haz")
    g.add_op("buf", kind="input")
    ann = {"effects": {"reads": ["buf"], "writes": ["buf"],
                       "carries": ["buf"]}}
    g.add_op("w1", deps=("buf",), fn=lambda b: b, meta=dict(ann))
    g.add_op("w2", deps=("buf",), fn=lambda b: b, meta=dict(ann))
    return g


def test_unordered_scatter_pair_flagged():
    rep = check_hazards(_two_writer_graph())
    assert any(f.rule_id == "H-WW" and f.severity == "error"
               for f in rep.findings)


def test_dep_ordered_writers_clean():
    g = Graph("haz-ok")
    g.add_op("buf", kind="input")
    ann = {"effects": {"reads": ["buf"], "writes": ["buf"],
                       "carries": ["buf"]}}
    g.add_op("w1", deps=("buf",), fn=lambda b: b, meta=dict(ann))
    g.add_op("w2", deps=("w1",), fn=lambda b: b, meta=dict(ann))
    assert check_hazards(g).ok


def test_placement_serialization_downgrades_to_warning():
    g = _two_writer_graph()
    sched = Schedule(
        graph_name=g.name, policy="manual", n_executors=1, team_size=1,
        makespan=2.0,
        placements={"buf": (0, 0.0, 0.0), "w1": (0, 0.0, 1.0),
                    "w2": (0, 1.0, 2.0)},
    )
    rep = check_hazards(g, schedule=sched)
    ww = [f for f in rep.findings if f.rule_id == "H-WW"]
    assert ww and all(f.severity == "warning" for f in ww)
    assert any("executor placement" in f.message for f in ww)


def test_cross_graph_write_write_error():
    g1, g2 = _two_writer_graph(), _two_writer_graph()
    rep = cross_graph_hazards(infer_effects(g1), infer_effects(g2),
                              [("buf", "buf")])
    assert any(f.rule_id == "H-XWW" for f in rep.errors)


# ---------------------------------------------------------------------------
# paged decode × prefill chunk: the PR 6 concurrency protocol, certified
# ---------------------------------------------------------------------------

def test_paged_pair_has_zero_write_conflicts():
    cfg = TINY["transformer"]
    assert transformer.paged_supported(cfg)
    params = transformer.init_params(cfg, jax.random.key(0))
    B, max_len, page = 2, 32, 8
    n_pt = max_len // page
    pcache = transformer.init_paged_cache(cfg, B, max_len,
                                          n_pages=B * n_pt, page_size=page)
    pages = pcache["pages"]         # ONE pool object bound by both graphs
    cache_spec = {"len": jnp.zeros((B,), jnp.int32),
                  "table": jnp.full((B, n_pt), -1, jnp.int32),
                  "pages": pages}
    tok = jnp.zeros((B, 1), jnp.int32)
    dec = repro.compile(make_paged_decode_step(cfg, page), params,
                        cache_spec, tok, name="chk.paged_decode")
    row = jnp.full((n_pt,), -1, jnp.int32)
    batch = {"tokens": jnp.zeros((1, page), jnp.int32)}
    start, valid = jnp.int32(0), jnp.int32(page)
    chunk = repro.compile(make_prefill_chunk_step(cfg, page), params, pages,
                          row, batch, start, valid, name="chk.prefill_chunk")

    eff_d = infer_effects(dec.graph)
    eff_c = infer_effects(chunk.graph)
    bind_d = dec.captured.bind((params, cache_spec, tok))
    bind_c = chunk.captured.bind((params, pages, row, batch, start, valid))
    shared = shared_buffers(bind_d, bind_c)
    pool_ids = {id(x) for x in jax.tree.leaves(pages)}
    pool_shared = [(a, b) for a, b in shared if id(bind_d[a]) in pool_ids]

    # decode writes the pools (the scan-body scatters were traced) ...
    assert pool_shared, "alias discovery found no shared pool buffers"
    assert eff_d.written() & {a for a, _ in pool_shared}
    # ... the chunk graph is certified read-only over every shared pool
    assert eff_c.read_only(b for _, b in pool_shared)
    # ... so the pair has zero unordered write/write conflicts
    rep = cross_graph_hazards(eff_d, eff_c, shared)
    assert not any(f.rule_id == "H-XWW" for f in rep.findings)
    assert rep.ok


# ---------------------------------------------------------------------------
# W-ASSERT source rule
# ---------------------------------------------------------------------------

def test_assertscan_library_tree_clean():
    rep = scan_asserts()
    assert rep.ok, rep.render()


def test_assertscan_flags_bare_assert(tmp_path):
    (tmp_path / "mod.py").write_text("def f(x):\n    assert x > 0\n    return x\n")
    rep = scan_asserts(tmp_path)
    hits = [f for f in rep.errors if f.rule_id == "W-ASSERT"]
    assert hits and "python -O" in hits[0].message


# ---------------------------------------------------------------------------
# API integration: check=, strict builds, Executable.verify()
# ---------------------------------------------------------------------------

def test_compile_rejects_unknown_check_mode():
    with pytest.raises(ValueError, match="check"):
        repro.compile(layered_graph(2, 2), n_workers=2, n_executors=2,
                      team_size=1, check="bogus")


def test_compile_basic_rejects_tampered_graph():
    g = layered_graph(2, 2)
    name = g.names[1]
    g._nodes[name] = dataclasses.replace(g[name], deps=(g.names[-1],))
    g._version += 1
    with pytest.raises(GraphValidationError, match="G-CYCLE"):
        repro.compile(g, n_workers=2, n_executors=2, team_size=1)
    # check="off" defers to the (later) scheduling failure instead
    exe = repro.compile(g, n_workers=2, n_executors=2, team_size=1,
                        check="off")
    assert exe.check == "off"


def test_strict_build_and_verify():
    g = layered_graph(3, 2)
    exe = repro.compile(g, n_workers=2, n_executors=2, team_size=1,
                        check="strict")
    plan = exe.host_plan(2)                     # strict-verified build
    assert plan.n_ops == len(g) - 1
    rep = exe.verify()
    assert rep.ok, rep.render()
    res = plan.run({"x": 1.0})
    assert res.outputs == copy.deepcopy(g).execute({"x": 1.0})
