"""Wavefront LSTM: stacked static plan vs sequential reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    diagonals,
    lstm_cell,
    recurrence_graph,
    sequential_lstm,
    stacked_wavefront_lstm,
)


def make_params(key, L, H, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {
        "Wx": (jax.random.normal(k1, (L, H, 4 * H)) * 0.1).astype(dtype),
        "Wh": (jax.random.normal(k2, (L, H, 4 * H)) * 0.1).astype(dtype),
        "b": jnp.zeros((L, 4 * H), dtype),
    }


@pytest.mark.parametrize("L,T,B,H", [(1, 1, 1, 8), (2, 3, 2, 8), (3, 7, 4, 16), (5, 2, 1, 8)])
def test_stacked_equals_sequential(L, T, B, H):
    key = jax.random.PRNGKey(L * 100 + T)
    params = make_params(key, L, H)
    xs = jax.random.normal(jax.random.fold_in(key, 7), (T, B, H))
    per_layer = [{k: v[l] for k, v in params.items()} for l in range(L)]
    ref = sequential_lstm(per_layer, xs)
    got = stacked_wavefront_lstm(params, xs, L)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


def test_stacked_jit_and_grad():
    L, T, B, H = 3, 4, 2, 8
    key = jax.random.PRNGKey(0)
    params = make_params(key, L, H)
    xs = jax.random.normal(jax.random.fold_in(key, 1), (T, B, H))

    @jax.jit
    def loss(p, xs):
        return jnp.sum(stacked_wavefront_lstm(p, xs, L) ** 2)

    g = jax.grad(loss)(params, xs)
    for k in params:
        assert g[k].shape == params[k].shape
        assert bool(jnp.all(jnp.isfinite(g[k])))


def test_diagonals_cover_grid():
    L, T = 4, 6
    cells = [c for wave in diagonals(L, T) for c in wave]
    assert len(cells) == L * T
    assert len(set(cells)) == L * T
    for d, wave in enumerate(diagonals(L, T)):
        for l, t in wave:
            assert l + t == d


def test_recurrence_graph_structure():
    g = recurrence_graph(3, 4)
    assert len(g) == 12
    assert g.width() == 3
    # corner deps
    assert g.predecessors("cell_L0_T0") == ()    # cached immutable tuple
    assert set(g.predecessors("cell_L1_T1")) == {"cell_L0_T1", "cell_L1_T0"}


def test_lstm_cell_shapes_and_finite():
    B, D, H = 3, 8, 8
    key = jax.random.PRNGKey(2)
    p = {
        "Wx": jax.random.normal(key, (D, 4 * H)) * 0.1,
        "Wh": jax.random.normal(key, (H, 4 * H)) * 0.1,
        "b": jnp.zeros((4 * H,)),
    }
    x = jnp.ones((B, D))
    h = jnp.zeros((B, H))
    c = jnp.zeros((B, H))
    h2, c2 = lstm_cell(p, x, h, c)
    assert h2.shape == (B, H) and c2.shape == (B, H)
    assert bool(jnp.all(jnp.isfinite(h2))) and bool(jnp.all(jnp.isfinite(c2)))
