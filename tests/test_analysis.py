"""HLO cost walker + roofline: validated against known-flop probes."""
import jax
import jax.numpy as jnp

from repro.analysis.hlo_cost import module_cost, parse_computations, top_traffic
from repro.analysis.hlo_collectives import collective_summary
from repro.analysis.roofline import roofline_report


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


def test_scan_trip_count_multiplies_flops():
    M, K, N, TRIPS = 64, 128, 128, 12

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=TRIPS)
        return out.sum()

    c = _compile(f, jax.ShapeDtypeStruct((M, K), jnp.float32),
                 jax.ShapeDtypeStruct((K, N), jnp.float32))
    mc = module_cost(c.as_text())
    expect = TRIPS * 2 * M * K * N
    assert expect <= mc.flops <= expect * 1.2, (mc.flops, expect)
    assert mc.unknown_trip_whiles == 0


def test_single_matmul_flops_exact():
    M, K, N = 128, 256, 192
    c = _compile(lambda x, w: x @ w,
                 jax.ShapeDtypeStruct((M, K), jnp.float32),
                 jax.ShapeDtypeStruct((K, N), jnp.float32))
    mc = module_cost(c.as_text())
    assert abs(mc.flops - 2 * M * K * N) / (2 * M * K * N) < 0.05


def test_nested_scan_trip_counts_compound():
    def f(x):
        def outer(c, _):
            def inner(d, _):
                return d * 1.0001 + 1.0, None
            d, _ = jax.lax.scan(inner, c, None, length=5)
            return d, None
        out, _ = jax.lax.scan(outer, x, None, length=7)
        return out.sum()

    c = _compile(f, jax.ShapeDtypeStruct((64,), jnp.float32))
    mc = module_cost(c.as_text())
    # inner body ~2 elementwise ops on 64 elts, x35 executions
    assert mc.flops >= 35 * 64, mc.flops


def test_bf16_dot_flops_not_double_counted():
    """CPU promotes bf16 dots to f32; flops must still be 2MKN, and the
    bf16-native byte model must charge less than the raw-f32 one."""
    M, K, N = 256, 256, 256
    c = _compile(lambda x, w: x @ w,
                 jax.ShapeDtypeStruct((M, K), jnp.bfloat16),
                 jax.ShapeDtypeStruct((K, N), jnp.bfloat16))
    txt = c.as_text()
    mc = module_cost(txt, bf16_native=True)
    mc_raw = module_cost(txt, bf16_native=False)
    assert abs(mc.flops - 2 * M * K * N) / (2 * M * K * N) < 0.05
    assert mc.bytes < mc_raw.bytes


def test_parse_computations_finds_entry():
    c = _compile(lambda x: x * 2.0, jax.ShapeDtypeStruct((8, 8), jnp.float32))
    comps, entry = parse_computations(c.as_text())
    assert entry and entry in comps


def test_top_traffic_ranks_by_bytes():
    c = _compile(lambda x, w: (x @ w).sum(),
                 jax.ShapeDtypeStruct((512, 512), jnp.float32),
                 jax.ShapeDtypeStruct((512, 512), jnp.float32))
    rows = top_traffic(c.as_text(), 5)
    assert rows and rows[0][0] >= rows[-1][0]


def test_roofline_report_terms_and_dominance():
    c = _compile(lambda x, w: x @ w,
                 jax.ShapeDtypeStruct((2048, 2048), jnp.bfloat16),
                 jax.ShapeDtypeStruct((2048, 2048), jnp.bfloat16))
    rep = roofline_report(
        arch="probe", shape="unit", mesh_desc="1x1", n_chips=1,
        hlo_text=c.as_text(), model_flops_total=2 * 2048 ** 3,
        bytes_per_device=1e9,
    )
    assert rep.compute_s > 0 and rep.memory_s > 0
    assert rep.dominant in ("compute", "memory", "collective")
    assert 0.5 < rep.useful_ratio <= 1.05   # one matmul: all flops useful
    assert rep.fits_hbm
    # big square bf16 matmul: arithmetic intensity ~683 flops/byte >> v5e
    # ridge point (~240), so compute must dominate
    assert rep.dominant == "compute"
    assert rep.mfu_bound() > 0.5


def test_collective_summary_empty_on_single_device():
    c = _compile(lambda x: x + 1.0, jax.ShapeDtypeStruct((64,), jnp.float32))
    stats = collective_summary(c.as_text())
    assert stats.total_bytes == 0
