"""Continuous-batching engine: per-request parity with unbatched greedy
decode, slot admission/eviction, profiler-bounded config search, and
multi-graph submission to one executor pool."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api as graphi
from repro.configs.base import get_config
from repro.core import KNL7250
from repro.core.engine import ExecutorPool, HostScheduler
from repro.core.profiler import enumerate_symmetric_configs, profile
from repro.models import transformer
from repro.serve.engine import ContinuousEngine, Request, ServeConfig, ServeEngine
from repro.serve.step import mask_pad_vocab


@pytest.fixture(scope="module")
def model():
    # padded_vocab (512) > vocab_size (260): the pad-mask is load-bearing
    cfg = get_config("gemma-2b", smoke=True).reduced(vocab_size=260)
    params = transformer.init_params(cfg, jax.random.key(3))
    return cfg, params


@pytest.fixture(scope="module")
def engine(model):
    cfg, params = model
    eng = ContinuousEngine(cfg, params, ServeConfig(max_batch=2, max_len=48))
    yield eng
    eng.close()


def _reference_decode(cfg, params, prompt, n_new):
    """Unbatched greedy reference (pad-masked argmax)."""
    cache = transformer.init_cache(cfg, 1, len(prompt) + n_new + 1)
    logits, cache = transformer.prefill(
        cfg, params, {"tokens": jnp.asarray(prompt)[None]}, cache)
    out = []
    for _ in range(n_new):
        t = int(jnp.argmax(mask_pad_vocab(logits, cfg.vocab_size), -1)[0])
        out.append(t)
        logits, cache = transformer.decode_step(
            cfg, params, jnp.asarray([[t]], jnp.int32), cache)
    return out


# ---------------------------------------------------------------------------
# parity: continuous mixed-length decode is bit-identical per request
# ---------------------------------------------------------------------------

def test_mixed_lengths_bit_identical_to_unbatched(model, engine):
    """4 mixed-length requests through 2 slots: admission waves, slot reuse,
    idle-slot garbage — every request must still match unbatched greedy."""
    cfg, params = model
    rng = np.random.default_rng(0)
    lens = [5, 11, 17, 8]
    prompts = [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32) for n in lens]
    for i, pr in enumerate(prompts):
        engine.submit(Request(request_id=i, prompt=pr, max_new_tokens=6))
    done = engine.run()
    assert [r.request_id for r in done] == [0, 1, 2, 3]      # submit order
    for r in done:
        ref = _reference_decode(cfg, params, r.prompt, 6)
        assert r.output == ref, (r.request_id, r.output, ref)
        assert all(t < cfg.vocab_size for t in r.output)


def test_eos_frees_slot_and_admits_within_one_step(model, engine):
    cfg, params = model
    rng = np.random.default_rng(1)
    pra = rng.integers(1, cfg.vocab_size, size=6).astype(np.int32)
    prb = rng.integers(1, cfg.vocab_size, size=9).astype(np.int32)
    prc = rng.integers(1, cfg.vocab_size, size=7).astype(np.int32)
    ref_a = _reference_decode(cfg, params, pra, 8)
    eos = ref_a[2]                      # A stops at its 3rd emitted token
    a = Request(request_id=10, prompt=pra, max_new_tokens=8, eos_id=eos)
    b = Request(request_id=11, prompt=prb, max_new_tokens=12)
    c = Request(request_id=12, prompt=prc, max_new_tokens=4)
    engine.submit(a)
    engine.submit(b)
    engine.step()                       # admit A+B (fills both slots)
    engine.submit(c)                    # queued: no free slot yet
    assert c in engine.pending
    while not a.done:
        engine.step()
    slot_a = engine.slots.index(None)   # A's slot freed mid-stream
    assert b in engine.slots
    engine.step()                       # ONE step: C admitted into A's slot
    assert engine.slots[slot_a] is c
    assert not engine.pending
    done = engine.run()
    assert [r.request_id for r in done] == [10, 11, 12]
    # the tiny model may emit eos before step 3 (greedy repetition) — the
    # contract under test is: stopped ON eos, well before the 8-token budget
    assert a.output[-1] == eos and len(a.output) <= 3
    assert b.output == _reference_decode(cfg, params, prb, 12)
    assert c.output == _reference_decode(cfg, params, prc, 4)


def test_temperature_sampling_stays_in_vocab(model):
    cfg, params = model
    rng = np.random.default_rng(2)
    with ContinuousEngine(cfg, params,
                          ServeConfig(max_batch=2, max_len=24, temperature=1.0)) as eng:
        for i in range(3):
            eng.submit(Request(request_id=i,
                               prompt=rng.integers(1, cfg.vocab_size, size=5).astype(np.int32),
                               max_new_tokens=8))
        done = eng.run()
    emitted = [t for r in done for t in r.output]
    assert emitted and all(0 <= t < cfg.vocab_size for t in emitted)


def test_submit_over_budget_raises(engine):
    with pytest.raises(ValueError, match="exceeds max_len"):
        engine.submit(Request(request_id=0, prompt=np.ones(40, np.int32),
                              max_new_tokens=40))


def test_submit_rejects_degenerate_requests(model, engine):
    cfg, params = model
    wave = ServeEngine(cfg, params, ServeConfig(max_batch=2, max_len=48))
    for eng in (engine, wave):
        with pytest.raises(ValueError, match="empty prompt"):
            eng.submit(Request(request_id=0, prompt=np.empty(0, np.int32)))
        with pytest.raises(ValueError, match="max_new_tokens"):
            eng.submit(Request(request_id=0, prompt=np.ones(4, np.int32),
                               max_new_tokens=0))
        with pytest.raises(ValueError, match="max_new_tokens"):
            eng.submit(Request(request_id=0, prompt=np.ones(4, np.int32),
                               max_new_tokens=-3))


# ---------------------------------------------------------------------------
# prefill bucketing: N prompt lengths compile O(log N) executables
# ---------------------------------------------------------------------------

def test_prefill_bucketing_bounds_executables(model):
    """100 distinct prompt lengths must compile at most O(log) prefill
    graphs (pow2 buckets, right-padded + valid-length-masked), and bucketed
    prefill must stay bit-identical to unbatched greedy."""
    cfg, params = model
    with ContinuousEngine(cfg, params,
                          ServeConfig(max_batch=2, max_len=128)) as eng:
        assert eng._bucket_prefill
        eng.warmup(range(1, 101))
        assert len(eng._prefill_exes) <= 8, sorted(eng._prefill_exes)
        # parity at bucket boundaries: exact pow2, pow2 +/- 1, interior
        rng = np.random.default_rng(5)
        lens = [1, 3, 8, 9, 33, 64]
        prompts = [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
                   for n in lens]
        for i, pr in enumerate(prompts):
            eng.submit(Request(request_id=i, prompt=pr, max_new_tokens=4))
        done = eng.run()
        assert len(eng._prefill_exes) <= 8       # no new graphs appeared
    for r in done:
        ref = _reference_decode(cfg, params, r.prompt, 4)
        assert r.output == ref, (len(r.prompt), r.output, ref)


def test_rejects_encoder_frontends(model):
    cfg, params = model
    bad = cfg.reduced(frontend="audio")
    with pytest.raises(ValueError, match="decoder-only"):
        ContinuousEngine(bad, params, ServeConfig(max_batch=2, max_len=16))


# ---------------------------------------------------------------------------
# profiler: max_executors bounds the config search
# ---------------------------------------------------------------------------

def _diamond():
    from repro.core import Graph

    g = Graph("diamond")
    g.add_op("a", flops=1e9)
    g.add_op("b", flops=2e9, deps=("a",))
    g.add_op("c", flops=3e9, deps=("a",))
    g.add_op("d", flops=4e9, deps=("b", "c"))
    return g


def test_enumerate_configs_respects_max_executors():
    bounded = enumerate_symmetric_configs(64, max_executors=4)
    assert bounded == [(1, 64), (2, 32), (4, 16)]
    assert enumerate_symmetric_configs(64)[-1][0] > 4


def test_profile_respects_max_executors():
    res = profile(_diamond(), KNL7250, n_workers=32, max_executors=2)
    assert all(n <= 2 for n, _ in res.config_makespans)
    assert res.best_n_executors <= 2


def test_profile_with_threads_max_executors():
    exe = graphi.compile(_diamond(), hw=KNL7250, backend="sim")
    unbounded = exe.profile
    assert any(n > 2 for n, _ in unbounded.config_makespans)
    bounded = exe.profile_with(max_executors=2)
    assert all(n <= 2 for n, _ in bounded.config_makespans)
    assert exe.profile is bounded                      # re-cached


def test_engine_honors_max_executors(model):
    cfg, params = model
    with ContinuousEngine(cfg, params, ServeConfig(max_batch=2, max_len=16),
                          max_executors=2) as eng:
        assert eng.n_executors <= 2
        assert all(n <= 2 for n, _ in eng.profile.config_makespans)


# ---------------------------------------------------------------------------
# ExecutorPool: multiple graphs share one pool
# ---------------------------------------------------------------------------

def _chain(name, k, base):
    from repro.core import Graph

    g = Graph(name)
    g.add_op("x0", flops=1.0, fn=lambda: base)
    for i in range(1, k):
        g.add_op(f"x{i}", deps=(f"x{i-1}",), flops=1.0, fn=lambda v: v + 1)
    return g


def test_two_graphs_run_concurrently_on_one_pool():
    with ExecutorPool(2) as pool:
        outs = {}

        def run(name, base):
            g = _chain(name, 6, base)
            outs[name] = HostScheduler(g, 2, pool=pool).run().outputs["x5"]

        ts = [threading.Thread(target=run, args=(f"g{i}", 100 * i)) for i in range(2)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert outs == {"g0": 5, "g1": 105}
        # the pool survives its runs: a third graph still executes
        g = _chain("g2", 3, 7)
        assert HostScheduler(g, 2, pool=pool).run().outputs["x2"] == 9


def test_pool_survives_a_failing_graph():
    from repro.core import Graph

    with ExecutorPool(1) as pool:
        bad = Graph("bad")
        bad.add_op("a", flops=1.0, fn=lambda: 1)
        bad.add_op("b", deps=("a",), flops=1.0,
                   fn=lambda v: (_ for _ in ()).throw(ValueError("boom")))
        with pytest.raises(RuntimeError, match="'b' failed"):
            HostScheduler(bad, 1, pool=pool).run()
        # the executor thread relayed the exception and kept serving
        g = _chain("ok", 3, 1)
        assert HostScheduler(g, 1, pool=pool).run().outputs["x2"] == 3


def test_executable_reuses_pool(model):
    def f(x):
        return jnp.tanh(x) @ x + 1.0

    x = jnp.ones((16, 16))
    with ExecutorPool(2) as pool:
        exe = graphi.compile(f, x, backend="host", pool=pool)
        out1 = exe(x)
        out2 = exe(x)
    assert jnp.allclose(out1, f(x)) and jnp.allclose(out2, f(x))
