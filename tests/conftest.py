"""Session bootstrap: src/ on sys.path (no PYTHONPATH=src needed), a forced
8-device host platform so single-process dist tests see a real mesh, the
``multidevice`` marker for the subprocess-based suite, and a graceful
stand-in for ``hypothesis`` when the dev extra isn't installed."""
import functools
import os
import sys
import types

# Must run before ANY jax import: the host device count locks at first init.
# The subprocess tests (test_dist_multidevice.py) override this per-child.
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    ).strip()

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "multidevice: subprocess-based multi-device tests (slow; spawn their own jax)",
    )
    config.addinivalue_line(
        "markers",
        "stress: fault-injection / concurrency stress tests (slow; CI runs "
        "them in a dedicated job under a hard wall-clock timeout)",
    )


# ---------------------------------------------------------------------------
# hypothesis stand-in: without the dev extra, property tests collect and SKIP
# (instead of failing the whole module at import); plain tests still run.
# ---------------------------------------------------------------------------

def _install_hypothesis_stub() -> None:
    import pytest

    reason = "hypothesis not installed (pip install -e .[dev])"

    class _Strategy:
        def __repr__(self):
            return "<hypothesis stub strategy>"

    def _strategy(*_a, **_k):
        return _Strategy()

    def composite(fn):
        @functools.wraps(fn)
        def build(*_a, **_k):
            return _Strategy()  # never drawn from: @given tests are skipped

        return build

    def given(*_a, **_k):
        def deco(fn):
            @pytest.mark.skip(reason=reason)
            @functools.wraps(fn)
            def wrapper():
                pass

            return wrapper

        return deco

    def settings(*_a, **_k):
        return lambda fn: fn

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.assume = lambda *_a, **_k: True
    st = types.ModuleType("hypothesis.strategies")
    st.composite = composite
    st.__getattr__ = lambda name: _strategy  # integers/floats/sampled_from/...
    hyp.strategies = st
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st


try:
    import hypothesis  # noqa: F401  (the real one, when installed)
except ImportError:
    _install_hypothesis_stub()
