"""Fast single-process dist coverage (conftest forces 8 host devices):
spec factories, the logical-axis shard() contract, ring collectives,
compressed psum, and the slot -> executor sub-mesh bridge."""
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro import api as graphi
from repro.configs.base import get_config
from repro.core import KNL7250
from repro.core.wavefront import recurrence_graph
from repro.dist.compress import compressed_psum
from repro.dist.executor_mesh import (
    executor_groups,
    executor_stacked_mesh,
    lane_pspec,
    plan_from_schedule,
)
from repro.dist.overlap import ring_allgather_matmul, ring_reducescatter_matmul
from repro.dist.sharding import (
    MeshCtx,
    batch_axes,
    batch_pspecs,
    cache_pspecs,
    mesh_context,
    param_pspecs,
    shard,
    use_mesh,
)
from repro.models import transformer


@pytest.fixture(scope="module")
def mesh():
    if jax.device_count() < 8:
        pytest.skip("needs 8 host devices (conftest XLA_FLAGS)")
    return jax.make_mesh((4, 2), ("data", "model"))


# ---------------------------------------------------------------------------
# sharding: context + spec factories
# ---------------------------------------------------------------------------

def test_shard_is_noop_without_mesh():
    x = jnp.ones((4, 4))
    assert mesh_context() is None
    assert shard(x, "batch", "model") is x


def test_shard_constrains_and_drops_indivisible(mesh):
    ctx = MeshCtx(mesh, batch_axes(mesh, 8))
    x = jnp.zeros((8, 6, 4))
    with use_mesh(ctx):
        y = jax.jit(lambda a: shard(a, "batch", None, "model"))(x)
        # dim0: 8 % data(4) == 0 -> sharded; dim2: 4 % model(2) == 0 -> sharded
        assert y.sharding.is_equivalent_to(
            jax.sharding.NamedSharding(mesh, P("data", None, "model")), 3
        )
        # indivisible dims drop their axis instead of erroring
        z = jnp.zeros((3, 5))
        w = jax.jit(lambda a: shard(a, "batch", "model"))(z)
        assert w.sharding.is_fully_replicated
    assert mesh_context() is None


def test_batch_axes_divisibility(mesh):
    assert batch_axes(mesh, 256) == ("data",)
    assert batch_axes(mesh, 2) == ()      # 2 % 4 != 0
    assert batch_axes(mesh, 1) == ()      # long_500k: B=1 never shards


def test_param_pspecs_megatron_rules(mesh):
    cfg = get_config("yi_9b")
    shapes = jax.eval_shape(lambda k: transformer.init_params(cfg, k), jax.random.key(0))
    specs = param_pspecs(cfg, shapes, mesh)
    assert specs["embed"] == P("model", None)
    assert specs["layers"]["attn"]["wq"] == P(None, None, "model")
    assert specs["layers"]["attn"]["wo"] == P(None, "model", None)
    assert specs["layers"]["mlp"]["w_down"] == P(None, "model", None)
    assert specs["layers"]["ln1"] == P(None, None)


def test_param_pspecs_fsdp_shards_over_data(mesh):
    cfg = get_config("yi_9b")
    shapes = jax.eval_shape(lambda k: transformer.init_params(cfg, k), jax.random.key(0))
    specs = param_pspecs(cfg, shapes, mesh, fsdp=True)
    flat = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
    n_data = sum(1 for s in flat if "data" in jax.tree.leaves(tuple(s)))
    assert n_data > 4, n_data


def test_batch_and_cache_pspecs(mesh):
    cfg = get_config("yi_9b", smoke=True)
    bp = batch_pspecs({"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32)}, mesh, 8)
    assert bp["tokens"] == P("data", None)
    cache = jax.eval_shape(lambda: transformer.init_cache(cfg, 8, 64))
    cp = cache_pspecs(cfg, cache, mesh, 8)
    assert cp["len"] == P()
    # stacked [L, B, C, H, hd]: batch over data, seq slots over model
    assert tuple(cp["layers"]["k"])[:3] == (None, "data", "model")


# ---------------------------------------------------------------------------
# collectives (in-process; the subprocess suite re-proves under fresh jax)
# ---------------------------------------------------------------------------

def test_ring_matmuls_match_reference_inprocess():
    if jax.device_count() < 8:
        pytest.skip("needs 8 host devices")
    m = jax.make_mesh((8,), ("model",))
    x = jax.random.normal(jax.random.key(0), (64, 32), jnp.float32)
    w = jax.random.normal(jax.random.key(1), (32, 48), jnp.float32)
    f = shard_map(partial(ring_allgather_matmul, axis_name="model"), mesh=m,
                  in_specs=(P("model", None), P(None, "model")), out_specs=P(None, "model"))
    g = shard_map(partial(ring_reducescatter_matmul, axis_name="model"), mesh=m,
                  in_specs=(P(None, "model"), P("model", None)), out_specs=P("model", None))
    np.testing.assert_allclose(jax.jit(f)(x, w), x @ w, atol=1e-4)
    np.testing.assert_allclose(jax.jit(g)(x, w), x @ w, atol=1e-4)


def test_compressed_psum_error_feedback_inprocess():
    if jax.device_count() < 8:
        pytest.skip("needs 8 host devices")
    m = jax.make_mesh((8,), ("pod",))
    g = jax.random.normal(jax.random.key(2), (8, 128), jnp.float32)
    h = shard_map(partial(compressed_psum, axis_name="pod"), mesh=m,
                  in_specs=(P("pod", None), P("pod", None)),
                  out_specs=(P("pod", None), P("pod", None)))
    gm, ne = jax.jit(h)(g, jnp.zeros_like(g))
    ref = g.mean(0)
    rel = float(jnp.abs(gm[0] - ref).max() / jnp.abs(ref).max())
    assert rel < 0.05
    gm2, _ = jax.jit(h)(g, ne)
    rel2 = float(jnp.abs((gm[0] + gm2[0]) / 2 - ref).max() / jnp.abs(ref).max())
    assert rel2 < rel + 0.01


# ---------------------------------------------------------------------------
# executor mesh bridge
# ---------------------------------------------------------------------------

def test_executor_groups_are_disjoint_and_cover(mesh):
    groups = executor_groups(mesh, 4)
    ids = [g.device_ids for g in groups]
    flat = [d for i in ids for d in i]
    assert len(flat) == len(set(flat)) == 8
    for g in groups:
        assert dict(g.mesh.shape) == {"data": 4, "model": 1} or \
               dict(g.mesh.shape) == {"data": 1, "model": 2}


def test_executor_stacked_mesh_splits_axis(mesh):
    sm = executor_stacked_mesh(mesh, 2, axis="model")
    assert sm.axis_names == ("data", "executor", "model")
    assert sm.shape["executor"] == 2 and sm.shape["model"] == 1
    assert lane_pspec(3) == P("executor", None, None)
    # a slot-stacked array actually places lanes on disjoint devices
    x = jnp.zeros((2, 4, 4))
    y = jax.device_put(x, jax.sharding.NamedSharding(sm, lane_pspec(3)))
    assert y.sharding.shard_shape(x.shape) == (1, 4, 4)
    lane_devs = [
        {s.device.id for s in y.addressable_shards if s.index[0] == slice(i, i + 1)}
        for i in range(2)
    ]
    assert lane_devs[0] and lane_devs[1] and not (lane_devs[0] & lane_devs[1])


def test_plan_from_schedule_slot_lanes(mesh):
    g = recurrence_graph(4, 6, flops_per_cell=1e6, bytes_per_cell=1e4)
    exe = graphi.compile(g, hw=KNL7250, backend="sim", n_executors=4, team_size=8)
    sched = exe.schedule
    plan = plan_from_schedule(g, sched, mesh, axis="data")
    assert sorted(plan.placement) == sorted(g.names)
    assert plan.n_executors == 4
    for slot in plan.slots:
        lanes = [plan.placement[op] for op in slot]
        assert len(set(lanes)) == len(lanes)        # one op per executor
        assert all(l < sched.n_executors for l in lanes)
    # deps never land in the same slot (barrier semantics)
    slot_of = {op: s for s, ops in enumerate(plan.slots) for op in ops}
    for n in g.names:
        for d in g.predecessors(n):
            assert slot_of[d] < slot_of[n]


def test_executable_static_plan_end_to_end(mesh):
    from repro.core import TPUV5E

    g = recurrence_graph(3, 5, flops_per_cell=1e9, bytes_per_cell=1e6)
    exe = graphi.compile(g, hw=TPUV5E, backend="sim", n_workers=8)
    plan = exe.static_plan(mesh, axis="data")
    assert sorted(plan.placement) == sorted(g.names)
    assert 1 <= plan.n_executors <= 4
