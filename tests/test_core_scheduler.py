"""Tests for cost model, simulator, schedulers, profiler, wavefront, engine."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    KNL7250,
    TPUV5E,
    Graph,
    HostScheduler,
    OpNode,
    SimConfig,
    diagonals,
    enumerate_symmetric_configs,
    is_wavefront_order,
    make_schedule,
    op_saturation_point,
    op_time,
    profile,
    recurrence_graph,
    sequential_makespan,
    simulate,
    slot_assignment,
)

GEMM = OpNode("gemm", kind="gemm", flops=2 * 64 * 512 * 512,
              bytes_in=(64 * 512 + 512 * 512) * 4, bytes_out=64 * 512 * 4)
ELTW = OpNode("mul", kind="elementwise", flops=32768,
              bytes_in=2 * 32768 * 4, bytes_out=32768 * 4)


# -------------------------- cost model ------------------------------------
def test_op_time_decreases_then_saturates_gemm():
    """Paper Fig 2a: the LSTM GEMM saturates around 8 KNL cores."""
    times = {k: op_time(KNL7250, GEMM, k) for k in (1, 2, 4, 8, 16, 32, 64)}
    assert times[1] > times[2] > times[4] > times[8]
    knee = op_saturation_point(KNL7250, GEMM)
    assert 4 <= knee <= 16
    # beyond the knee: no better than 10% further gain
    assert times[64] > 0.9 * times[knee]


def test_op_time_eltwise_saturates_later_but_small():
    """Paper Fig 2b: 32k elementwise saturates ~16 cores."""
    knee = op_saturation_point(KNL7250, ELTW)
    assert 8 <= knee <= 32


def test_parallel_ops_beat_one_wide_op():
    """Paper §3.2: >6x more FLOPS running 8 GEMMs on 8-core teams than one
    GEMM on 64 cores (per-op times barely differ -> throughput scales)."""
    t_wide = op_time(KNL7250, GEMM, 64)
    t_narrow = op_time(KNL7250, GEMM, 8)
    flops_wide = GEMM.flops / t_wide
    flops_8x = 8 * GEMM.flops / t_narrow
    assert flops_8x > 4 * flops_wide


def test_tpu_collective_term():
    big = OpNode("mm", flops=2e12, bytes_in=2e9, bytes_out=1e8)
    t_no = op_time(TPUV5E, big, 8, tp_collective=False)
    t_yes = op_time(TPUV5E, big, 8, tp_collective=True)
    assert t_yes > t_no


def test_op_time_validations():
    with pytest.raises(ValueError):
        op_time(KNL7250, GEMM, 0)


# -------------------------- simulator -------------------------------------
def chain_graph(n=5, flops=1e7):
    g = Graph("chain")
    prev = None
    for i in range(n):
        g.add_op(f"c{i}", flops=flops, deps=(prev,) if prev else ())
        prev = f"c{i}"
    return g


def wide_graph(n=8, flops=3e7):
    g = Graph("wide")
    g.add_op("src", flops=1e3)
    for i in range(n):
        g.add_op(f"w{i}", flops=flops, deps=("src",))
    g.add_op("sink", flops=1e3, deps=tuple(f"w{i}" for i in range(n)))
    return g


def test_chain_has_no_parallel_speedup():
    g = chain_graph()
    r1 = simulate(g, KNL7250, SimConfig(1, 32, "cpf"))
    r4 = simulate(g, KNL7250, SimConfig(4, 8, "cpf"))
    # a chain cannot go faster with more executors at fixed team size 8 vs 32
    assert r4.makespan >= 0.5 * r1.makespan


def test_wide_graph_parallel_speedup():
    g = wide_graph(8)
    seq = simulate(g, KNL7250, SimConfig(1, 64, "cpf")).makespan
    par = simulate(g, KNL7250, SimConfig(8, 8, "cpf")).makespan
    assert par < seq  # paper Fig 6: parallel beats sequential on wide graphs


def test_simulator_respects_dependencies_and_exclusivity():
    g = wide_graph(6)
    res = simulate(g, KNL7250, SimConfig(3, 8, "random"), seed=7)
    ends = {e.op: e.end for e in res.trace}
    starts = {e.op: e.start for e in res.trace}
    for node in g.nodes:
        for d in node.deps:
            assert ends[d] <= starts[node.name] + 1e-12
    by_exec = res.executor_timeline()
    for evs in by_exec.values():
        for a, b in zip(evs, evs[1:]):
            assert a.end <= b.start + 1e-12


def test_contention_hurts_naive_queue():
    g = wide_graph(16, flops=5e5)  # many small ops -> dispatch-bound
    base = SimConfig(16, 4, "fifo", queue_base_cost=0.0, queue_contention_cost=0.0)
    cont = SimConfig(16, 4, "fifo", queue_base_cost=1e-6, queue_contention_cost=2e-6)
    assert (
        simulate(g, KNL7250, cont).makespan > simulate(g, KNL7250, base).makespan
    )


def test_cpf_beats_or_ties_naive_on_recurrence():
    g = recurrence_graph(4, 8, flops_per_cell=3e7, bytes_per_cell=1e6)
    cpf = simulate(g, KNL7250, SimConfig(4, 16, "cpf")).makespan
    worst_naive = max(
        simulate(g, KNL7250, SimConfig(4, 16, "random"), seed=s).makespan
        for s in range(5)
    )
    assert cpf <= worst_naive + 1e-12


# -------------------------- scheduler / slots ------------------------------
def test_schedule_valid_and_slots_legal():
    g = recurrence_graph(3, 5, flops_per_cell=3e7)
    sched = make_schedule(g, KNL7250, n_executors=3, team_size=8)
    sched.validate(g)
    slots = slot_assignment(g, sched)
    assert sum(len(s) for s in slots) == len(g)
    assert max(len(s) for s in slots) <= 3
    # every dep in a strictly earlier slot
    slot_of = {n: i for i, s in enumerate(slots) for n in s}
    for node in g.nodes:
        for d in node.deps:
            assert slot_of[d] < slot_of[node.name]


def test_cpf_recovers_wavefront():
    """Paper §7.4: critical-path-first recovers cuDNN's diagonal schedule."""
    L, T = 4, 10
    g = recurrence_graph(L, T, flops_per_cell=3e7, bytes_per_cell=1e6)
    sched = make_schedule(g, KNL7250, n_executors=L, team_size=8, policy="cpf")
    assert is_wavefront_order(sched.start_order(), g)
    # matches the reference diagonals
    diags = diagonals(L, T)
    order = sched.start_order()
    i = 0
    for d, wave in enumerate(diags):
        names = {f"cell_L{l}_T{t}" for l, t in wave}
        got = set(order[i : i + len(wave)])
        assert got == names, f"diagonal {d}: {got} != {names}"
        i += len(wave)


# -------------------------- profiler ---------------------------------------
def test_enumerate_symmetric_configs():
    cfgs = enumerate_symmetric_configs(64)
    assert (1, 64) in cfgs and (64, 1) in cfgs and (8, 8) in cfgs
    cfgs66 = enumerate_symmetric_configs(66)
    assert (4, 16) in cfgs66  # floor division (paper leaves 2 cores idle)


def test_profile_picks_width_matched_config():
    """Paper §7.3: optimal #executors tracks the graph's parallel width."""
    g = wide_graph(8, flops=3e7)
    p = profile(g, KNL7250, n_workers=64)
    assert p.best_n_executors >= 4
    # a chain has no inter-op parallelism: the best makespan equals running
    # each op at its own saturation team size, back to back (extra executors
    # sit idle; team beyond the knee only adds barrier overhead).
    chain = chain_graph(6, flops=3e7)
    p2 = profile(chain, KNL7250, n_workers=64)
    seq_at_best_team = sequential_makespan(KNL7250, chain, p2.best_team_size)
    # profile() charges the scheduler's per-op dispatch cost; allow for it
    assert p2.best_makespan == pytest.approx(seq_at_best_team, rel=1e-2)


# -------------------------- host runtime -----------------------------------
def test_host_scheduler_matches_sequential_interpreter():
    rng = np.random.default_rng(0)
    x0 = rng.standard_normal(32)
    g = Graph("host")
    g.add_op("x", fn=lambda: x0)
    for i in range(10):
        deps = ("x",) if i < 3 else (f"op{i-3}", f"op{i-2}")
        g.add_op(f"op{i}", deps=deps[: 1 + i % 2],
                 fn=lambda *a: sum(np.tanh(v) for v in a))
    ref = g.execute()
    for n_exec in (1, 2, 4):
        out = HostScheduler(g, n_exec).run().outputs
        for k in ref:
            np.testing.assert_allclose(out[k], ref[k], rtol=1e-12)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 4), st.integers(0, 10_000))
def test_host_scheduler_property_random_dags(n_exec, seed):
    rng = np.random.default_rng(seed)
    g = Graph("prop")
    n = int(rng.integers(3, 15))
    for i in range(n):
        pool = list(range(i))
        k = int(rng.integers(0, min(3, i) + 1)) if pool else 0
        deps = tuple(f"v{j}" for j in rng.choice(pool, size=k, replace=False)) if k else ()
        if deps:
            g.add_op(f"v{i}", deps=deps, fn=lambda *a: np.sum([x.sum() for x in a]) + np.ones(4))
        else:
            val = rng.standard_normal(4)
            g.add_op(f"v{i}", fn=lambda v=val: v)
    ref = g.execute()
    out = HostScheduler(g, n_exec).run().outputs
    for key in ref:
        np.testing.assert_allclose(out[key], ref[key], rtol=1e-10)


# -------------------------- api end to end ---------------------------------
def test_executable_end_to_end():
    from repro import api as graphi

    g = recurrence_graph(4, 6, flops_per_cell=3e7, bytes_per_cell=1e6)
    exe = graphi.compile(g, hw=KNL7250, backend="sim")
    p = exe.profile
    assert p.best_makespan <= sequential_makespan(KNL7250, g, exe.usable_workers)
    s = exe.schedule
    s.validate(g)
    assert sum(map(len, exe.slots)) == len(g)
