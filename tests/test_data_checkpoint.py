"""Data pipeline determinism/sharding + checkpoint atomicity/restore."""
import os
import tempfile

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.checkpoint import (
    CheckpointManager,
    latest_step,
    load_checkpoint,
    restore_state,
    save_checkpoint,
)
from repro.checkpoint.store import list_steps
from repro.data import DataConfig, Prefetcher, SyntheticTokens


def _src(**kw):
    base = dict(vocab_size=128, seq_len=32, global_batch=8, seed=11)
    base.update(kw)
    return SyntheticTokens(DataConfig(**base))


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_batches_deterministic_by_step():
    a, b = _src(), _src()
    for step in (0, 1, 17, 100_000):
        x, y = a.batch(step), b.batch(step)
        assert np.array_equal(x["tokens"], y["tokens"])
        assert np.array_equal(x["labels"], y["labels"])


def test_labels_are_next_tokens():
    b = _src().batch(3)
    assert np.array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_different_steps_differ():
    s = _src()
    assert not np.array_equal(s.batch(0)["tokens"], s.batch(1)["tokens"])


def test_host_slicing_partitions_global_batch():
    s = _src()
    g = s.batch(5)["tokens"]
    parts = [s.host_batch(5, h, 4)["tokens"] for h in range(4)]
    assert np.array_equal(np.concatenate(parts), g)


def test_bigram_structure_learnable():
    """Successor of token t equals table[t] ~90% of the time."""
    s = _src(seq_len=256, global_batch=16)
    b = s.batch(0)["tokens"]
    hits = (s._table[b[:, :-1]] == b[:, 1:]).mean()
    assert 0.8 < hits < 0.97, hits


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(0, 10_000))
def test_property_step_isolation(s1, s2):
    src = _src()
    a, b = src.batch(s1), src.batch(s2)
    if s1 == s2:
        assert np.array_equal(a["tokens"], b["tokens"])


def test_prefetcher_matches_direct_and_handles_restart():
    src = _src()
    pf = Prefetcher(src, start_step=0, depth=3)
    try:
        for i in range(5):
            assert np.array_equal(pf.get(i)["tokens"], src.batch(i)["tokens"])
        # simulate restart: jump back
        assert np.array_equal(pf.get(2)["tokens"], src.batch(2)["tokens"])
    finally:
        pf.close()


def test_prefetcher_close_is_prompt_and_quiet(caplog):
    import logging

    pf = Prefetcher(_src(), start_step=0, depth=2)
    pf.get(0)
    with caplog.at_level(logging.WARNING, logger="repro.data.pipeline"):
        pf.close()
    assert not pf._thread.is_alive()
    assert not caplog.records          # healthy producer: no stuck warning


def test_prefetcher_close_names_stuck_stage(caplog):
    """A producer wedged inside its generator cannot be interrupted, but
    close() must say so — naming the stage — instead of silently leaking
    the thread (ISSUE 9 satellite)."""
    import logging
    import threading

    release = threading.Event()
    wedged = threading.Event()
    producer = threading.current_thread()   # replaced below

    class WedgedSource:
        def __init__(self):
            self.cfg = DataConfig(vocab_size=7, seq_len=4, global_batch=2)

        def batch(self, step):
            # wedge only inside the producer thread: on a slow box get(0)
            # may race the first enqueue and take the direct-call path —
            # the *main* thread must never block here (it would stall 30s
            # and let the producer exit before close() looks at it)
            if step > 0 and threading.current_thread() is producer:
                wedged.set()
                release.wait(30)
            return SyntheticTokens(self.cfg).batch(step)

    pf = Prefetcher(WedgedSource(), start_step=0, depth=1)
    producer = pf._thread
    try:
        pf.get(0)
        assert wedged.wait(10), "producer never reached the wedge"
        with caplog.at_level(logging.WARNING, logger="repro.data.pipeline"):
            pf.close(timeout=0.3)
        stuck = [r for r in caplog.records if "stuck in" in r.message]
        assert stuck, "close() abandoned the producer silently"
        assert "generate(step=" in stuck[0].message
    finally:
        release.set()
        pf._thread.join(timeout=5)
        assert not pf._thread.is_alive()


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def _state():
    return {
        "params": {"w": jnp.arange(12.0).reshape(3, 4), "layers": [jnp.ones(5), jnp.zeros(2)]},
        "m": {"w": jnp.full((3, 4), 0.5), "layers": [jnp.ones(5) * 2, jnp.ones(2)]},
        "step": jnp.asarray(9, jnp.int32),
    }


def test_roundtrip_exact():
    s = _state()
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 42, s)
        step, flat = load_checkpoint(d)
        assert step == 42
        out = restore_state(s, flat)
        for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(out)):
            assert np.array_equal(np.asarray(a), np.asarray(b))


import jax  # noqa: E402  (used above in tree_leaves)


def test_keep_n_prunes_old():
    s = _state()
    with tempfile.TemporaryDirectory() as d:
        for i in range(5):
            save_checkpoint(d, i, s, keep=2)
        assert list_steps(d) == [3, 4]


def test_crash_mid_save_never_corrupts_latest():
    """A .tmp dir left by a 'crashed' save is invisible to restore."""
    s = _state()
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, s)
        # fake a crashed save: stale tmp dir with garbage
        os.makedirs(os.path.join(d, "step_00000002.tmp"))
        with open(os.path.join(d, "step_00000002.tmp", "state.npz"), "w") as f:
            f.write("garbage")
        assert latest_step(d) == 1
        step, flat = load_checkpoint(d)
        assert step == 1 and "step" in flat


def test_missing_leaf_raises():
    s = _state()
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 0, s)
        _, flat = load_checkpoint(d)
        del flat["params/w"]
        with pytest.raises(KeyError):
            restore_state(s, flat)


def test_manager_async_save_and_restore():
    s = _state()
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=3, async_save=True)
        mgr.save(10, s)
        mgr.wait()
        step, out = mgr.restore(s)
        assert step == 10
        assert np.array_equal(np.asarray(out["params"]["w"]), np.asarray(s["params"]["w"]))


def test_restore_casts_to_template_dtype():
    s = _state()
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 0, s)
        _, flat = load_checkpoint(d)
        tmpl = jax.tree.map(lambda x: x.astype(jnp.float64) if x.dtype == jnp.float32 else x, s)
        out = restore_state(tmpl, flat)
        assert out["params"]["w"].dtype == tmpl["params"]["w"].dtype
