"""Shared benchmark plumbing: result rows, band checks, CSV."""
from __future__ import annotations

from dataclasses import dataclass

__all__ = ["Row", "check_band", "format_table", "to_csv"]


@dataclass
class Row:
    bench: str
    name: str
    value: float
    unit: str
    source: str = "model:KNL"       # measured | model:KNL | model:v5e
    note: str = ""
    check: str = ""                 # PASS / WARN / (empty = informational)


def check_band(value: float, lo: float, hi: float, *, slack: float = 0.0) -> str:
    """PASS inside [lo, hi] (± slack x width), WARN outside."""
    w = (hi - lo) * slack
    return "PASS" if (lo - w) <= value <= (hi + w) else "WARN"


def format_table(rows: list[Row]) -> str:
    out = [f"{'benchmark':24s} {'metric':42s} {'value':>12s} {'unit':10s} {'src':10s} {'check':5s}"]
    for r in rows:
        out.append(
            f"{r.bench:24s} {r.name:42s} {r.value:12.4g} {r.unit:10s} {r.source:10s} {r.check:5s}"
            + (f"  # {r.note}" if r.note else "")
        )
    return "\n".join(out)


def to_csv(rows: list[Row]) -> str:
    lines = ["bench,name,value,unit,source,check,note"]
    for r in rows:
        note = r.note.replace(",", ";")
        lines.append(f"{r.bench},{r.name},{r.value},{r.unit},{r.source},{r.check},{note}")
    return "\n".join(lines)
