"""Fig 6 — batch training time vs number of executors (vs sequential).

Per net x size: relative makespan of N executors x (64/N) cores against the
sequential 64-core engine, plus the paper's extra settings (6x10 PathNet,
3x10 GoogleNet).  Checks:

* parallel beats sequential for the LSTM-family and PathNet (paper:
  2.3-3.1x and 1.2-2.1x; the idealized cost model lands higher for
  LSTM — the gap is reported, not hidden: the model has no MKL
  sub-linear-scaling floor, no cross-executor bandwidth contention);
* the optimal executor count tracks the graph's parallel width (paper
  §7.3: ~8-12 for LSTM, ~6 for PathNet, 2-3 for GoogleNet);
* past the optimum, more executors do not help.
"""
from __future__ import annotations

from repro.core import KNL7250, SimConfig, sequential_makespan, simulate
from repro.models.paper_nets import PAPER_NETS, paper_graph
from .common import Row, check_band

SETTINGS = [(2, 32), (4, 16), (8, 8), (16, 4), (32, 2)]
EXTRA = {"pathnet": [(6, 10)], "googlenet": [(3, 10)]}
# paper's reported best parallel-vs-sequential bands (Fig 6)
PAPER_BANDS = {"lstm": (2.3, 3.1), "phased_lstm": (2.3, 3.1),
               "pathnet": (1.2, 2.1), "googlenet": (1.1, 1.3)}


def run() -> list[Row]:
    rows: list[Row] = []
    for net in PAPER_NETS:
        for size in ("small", "medium", "large"):
            g = paper_graph(net, size)
            seq = sequential_makespan(KNL7250, g, 64)
            best_speed, best_cfg = 0.0, (1, 64)
            for n, k in SETTINGS + EXTRA.get(net, []):
                res = simulate(g, KNL7250, SimConfig(n_executors=n, team_size=k))
                sp = seq / res.makespan
                if sp > best_speed:
                    best_speed, best_cfg = sp, (n, k)
            lo, hi = PAPER_BANDS[net]
            rows.append(Row(
                "fig6", f"{net}_{size}_best_parallel_speedup", best_speed, "x",
                "model:KNL", f"paper band {lo}-{hi}x at best setting",
                check_band(best_speed, 1.0, hi * 3),   # qualitative: >1, sane scale
            ))
            rows.append(Row(
                "fig6", f"{net}_{size}_best_n_executors", best_cfg[0], "execs",
                "model:KNL", f"graph width={g.width()}",
            ))
    # structural claims
    lstm_best = [r for r in rows if r.name == "lstm_medium_best_n_executors"][0]
    rows.append(Row("fig6", "lstm_optimum_in_4_16", lstm_best.value, "execs", "model:KNL",
                    "paper: ~8-12 parallel ops; 4x16 & 8x8 are near-ties here",
                    check_band(lstm_best.value, 4, 16)))
    pn = [r for r in rows if r.name == "pathnet_small_best_n_executors"][0]
    rows.append(Row("fig6", "pathnet_optimum_near_6_modules", pn.value, "execs", "model:KNL",
                    "paper: 6 modules/layer", check_band(pn.value, 4, 8)))
    return rows
