"""Fig 2 — scalability of typical DL ops on the manycore CPU.

Paper: GEMM [64,512]x[512,512] (MKL) saturates at ~8 cores; a 32k-element
elementwise multiply at ~16.  We reproduce the knees from the calibrated
KNL cost model and report the speedup-at-saturation, plus the same ops on
the TPU-v5e worker model (the transfer the rest of the system relies on).

[measured] rows: wall-clock of the actual jnp ops on this container's CPU
for the same shapes — single-core, so only the per-op *cost ratio* (GEMM vs
elementwise) is checkable, not the knee.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import KNL7250, TPUV5E, OpNode, op_saturation_point, op_time
from .common import Row, check_band

GEMM = OpNode("gemm", kind="gemm", flops=2 * 64 * 512 * 512,
              bytes_in=(64 * 512 + 512 * 512) * 4, bytes_out=64 * 512 * 4,
              meta={"rows": 64})
ELTWISE = OpNode("eltwise", kind="elementwise", flops=32768,
                 bytes_in=2 * 32768 * 4, bytes_out=32768 * 4)


def run() -> list[Row]:
    rows: list[Row] = []
    for hw, tag in ((KNL7250, "knl"), (TPUV5E, "v5e")):
        for op, paper_knee in ((GEMM, 8), (ELTWISE, 16)):
            k = op_saturation_point(hw, op)
            speedup = op_time(hw, op, 1) / op_time(hw, op, k)
            check = check_band(k, paper_knee / 2, paper_knee * 2) if tag == "knl" else ""
            rows.append(Row("fig2", f"{op.name}_saturation_cores[{tag}]", k, "cores",
                            f"model:{tag}", f"paper knee ~{paper_knee} (knl)", check))
            rows.append(Row("fig2", f"{op.name}_speedup_at_knee[{tag}]", speedup, "x",
                            f"model:{tag}"))

    # measured single-core cost ratio of the two ops (sanity for the model)
    import jax
    import jax.numpy as jnp

    a = jnp.asarray(np.random.rand(64, 512), jnp.float32)
    b = jnp.asarray(np.random.rand(512, 512), jnp.float32)
    c = jnp.asarray(np.random.rand(32768), jnp.float32)
    gemm_fn = jax.jit(lambda a, b: a @ b)
    ew_fn = jax.jit(lambda c: c * c)
    gemm_fn(a, b).block_until_ready(); ew_fn(c).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(50):
        gemm_fn(a, b).block_until_ready()
    t_gemm = (time.perf_counter() - t0) / 50
    t0 = time.perf_counter()
    for _ in range(50):
        ew_fn(c).block_until_ready()
    t_ew = (time.perf_counter() - t0) / 50
    measured_ratio = t_gemm / t_ew
    model_ratio = op_time(KNL7250, GEMM, 1) / op_time(KNL7250, ELTWISE, 1)
    rows.append(Row("fig2", "gemm/eltwise_cost_ratio_measured_cpu", measured_ratio, "x", "measured"))
    rows.append(Row("fig2", "gemm/eltwise_cost_ratio_model_1core", model_ratio, "x", "model:KNL",
                    "order-of-magnitude agreement expected",
                    check_band(measured_ratio / model_ratio, 0.1, 10.0)))
    return rows
