"""Table 2 — Graphi CPF scheduler vs the naive shared-queue scheduler.

Interference-free comparison (the paper isolates the scheduler): same
graphs, same executor settings, only the policy and its dispatch-path costs
differ.  Naive = every idle executor polls one global queue (serialized
dequeue whose cost grows with the number of concurrent pollers); CPF =
centralized level-ordered push into per-executor buffers.

Paper: Graphi/naive relative time 0.81-0.96 on medium nets across five
parallelism settings (8-19% speedup), larger for LSTM-family (more small
ops -> more queue contention), smaller for GoogleNet (big ops).
"""
from __future__ import annotations

from repro.core import KNL7250, SimConfig, get_policy, simulate
from repro.models.paper_nets import PAPER_NETS, paper_graph
from .common import Row, check_band

SETTINGS = [(2, 32), (4, 16), (8, 8), (16, 4), (32, 2)]

# the Graphi-side policy under comparison resolves through the policy
# registry (repro.core.policies) — swap in any registered name to rerun the
# table under a different priority heuristic
GRAPHI_POLICY = "cpf"


JITTER = 0.15   # declared calibration: ±15% per-op runtime variation — the
#                 paper's own premise ("unpredictable variations at run
#                 time", §4.3) and what CPF priority protects against
SEEDS = tuple(range(6))


def run() -> list[Row]:
    rows: list[Row] = []
    best_gain = {}
    graphi_policy = get_policy(GRAPHI_POLICY)   # fail fast on unknown names
    for net in PAPER_NETS:
        g = paper_graph(net, "medium")
        ratios = []
        for n, k in SETTINGS:
            rs = []
            for seed in SEEDS:
                cpf = simulate(g, KNL7250, SimConfig(n_executors=n, team_size=k,
                                                     policy=graphi_policy, jitter=JITTER), seed=seed)
                naive = simulate(g, KNL7250, SimConfig(n_executors=n, team_size=k,
                                                       policy="random", jitter=JITTER), seed=seed)
                rs.append(cpf.makespan / naive.makespan)
            ratio = sum(rs) / len(rs)
            ratios.append(ratio)
            rows.append(Row("table2", f"{net}_medium_{n}x{k}_cpf_over_naive",
                            ratio, "ratio", "model:KNL"))
        best_gain[net] = 1.0 - min(ratios)
    for net, gain in best_gain.items():
        band = (0.04, 0.25) if net != "googlenet" else (0.0, 0.15)
        rows.append(Row("table2", f"{net}_best_scheduler_gain", gain * 100, "%",
                        "model:KNL", "paper: 8-19% (LSTM-ish high, GoogleNet low)",
                        check_band(gain, *band)))
    # ordering claim: LSTM-family gains exceed GoogleNet's
    ok = min(best_gain["lstm"], best_gain["phased_lstm"]) >= best_gain["googlenet"]
    rows.append(Row("table2", "lstm_gain_exceeds_googlenet", float(ok), "bool",
                    "model:KNL", "", "PASS" if ok else "WARN"))
    return rows
