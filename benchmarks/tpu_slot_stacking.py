"""Beyond-paper — the Graphi translation to a TPU pod (DESIGN.md §2.1).

Three claims, all on the v5e hardware model / real JAX artifacts:

1. **CPF recovers the cuDNN diagonal** (paper §7.4): critical-path-first
   scheduling of an L x T recurrence DAG visits cells in non-decreasing
   anti-diagonal order — checked structurally, not by timing.
2. **Slot stacking wins on the pod model**: scheduling the recurrence on
   N executor groups (simulated with v5e worker costs) beats 1-group
   sequential by ~the wavefront width, exactly the paper's Fig-6 shape.
3. **The stacked wavefront LSTM is numerically exact**: the jitted
   stacked-diagonal plan equals the sequential lax.scan reference (the
   static-plan compiler's correctness contract).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import api as graphi
from repro.core import (
    TPUV5E,
    SimConfig,
    is_wavefront_order,
    recurrence_graph,
    sequential_lstm,
    sequential_makespan,
    simulate,
    stacked_wavefront_lstm,
)
from .common import Row, check_band

L, T, B, H = 8, 24, 32, 256


def run() -> list[Row]:
    rows: list[Row] = []
    # per-cell cost: 2 GEMMs [B,H]x[H,4H] + gates, on one v5e chip
    flops = 2 * 2 * B * H * 4 * H
    byts = (2 * B * H + 2 * H * 4 * H) * 2
    g = recurrence_graph(L, T, flops_per_cell=flops, bytes_per_cell=byts)

    exe = graphi.compile(g, hw=TPUV5E, backend="sim", n_workers=64, reserved_workers=0)
    prof = exe.profile
    sched = simulate(g, TPUV5E, SimConfig(n_executors=prof.best_n_executors,
                                          team_size=prof.best_team_size))
    order = sched.start_order()
    diag_ok = is_wavefront_order(order, g)
    rows.append(Row("tpu_stack", "cpf_recovers_diagonal", float(diag_ok), "bool",
                    "model:v5e", "paper §7.4 cuDNN pattern", "PASS" if diag_ok else "WARN"))
    used = len({e.executor for e in sched.trace})
    rows.append(Row("tpu_stack", "executor_groups_active", used, "groups",
                    "model:v5e", f"schedule keeps >= wavefront width ({L}) busy",
                    check_band(used, L, 64)))

    seq = sequential_makespan(TPUV5E, g, 64)
    speed = seq / sched.makespan
    # two stacked terms: width parallelism (~L) x per-op dispatch-alpha
    # amortization (sequential pays alpha per cell; the diagonal plan per
    # slot) — on a dispatch-bound recurrence the product far exceeds L
    rows.append(Row("tpu_stack", "stacked_vs_sequential_makespan", speed, "x",
                    "model:v5e", "width x dispatch-batching; >L expected",
                    check_band(speed, 1.5, (L + T) * 2)))

    # numerical exactness of the static plan
    key = jax.random.key(0)
    ks = jax.random.split(key, 4)
    stacked = {
        "Wx": jax.random.normal(ks[0], (L, H, 4 * H), jnp.float32) * 0.05,
        "Wh": jax.random.normal(ks[1], (L, H, 4 * H), jnp.float32) * 0.05,
        "b": jax.random.normal(ks[2], (L, 4 * H), jnp.float32) * 0.05,
    }
    xs = jax.random.normal(ks[3], (T, B, H), jnp.float32)
    per_layer = [jax.tree.map(lambda p, i=i: p[i], stacked) for i in range(L)]
    ref = sequential_lstm(per_layer, xs)
    out = jax.jit(stacked_wavefront_lstm, static_argnums=2)(stacked, xs, L)
    err = float(jnp.abs(out - ref).max())
    rows.append(Row("tpu_stack", "stacked_wavefront_max_err", err, "abs",
                    "measured", "vs sequential lax.scan reference",
                    "PASS" if err < 1e-4 else "WARN"))
    return rows
