"""Benchmark harness: one module per paper table/figure + the beyond-paper
TPU translation.  ``python -m benchmarks.run [--only fig5] [--csv out.csv]``.

Every row carries its provenance ([measured] on this CPU vs [model:KNL] /
[model:v5e] cost-model replay — see DESIGN.md §5) and, where the paper
publishes a number, a PASS/WARN band check.
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time

from .common import Row, format_table, to_csv

MODULES = [
    "fig2_op_scalability",
    "fig3_interference",
    "fig5_overall",
    "fig6_executor_sweep",
    "table2_scheduler",
    "section6_affinity",
    "tpu_slot_stacking",
]


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--only", default=None, help="substring filter on module names")
    p.add_argument("--csv", default="results/benchmarks.csv")
    args = p.parse_args()

    rows: list[Row] = []
    for name in MODULES:
        if args.only and args.only not in name:
            continue
        mod = importlib.import_module(f"benchmarks.{name}")
        t0 = time.time()
        rows.extend(mod.run())
        print(f"[{name}] done in {time.time()-t0:.1f}s", file=sys.stderr)

    print(format_table(rows))
    n_warn = sum(1 for r in rows if r.check == "WARN")
    n_pass = sum(1 for r in rows if r.check == "PASS")
    print(f"\n{n_pass} PASS / {n_warn} WARN / {len(rows) - n_pass - n_warn} info")

    if args.csv:
        import os

        os.makedirs(os.path.dirname(args.csv) or ".", exist_ok=True)
        with open(args.csv, "w") as f:
            f.write(to_csv(rows))
        print(f"wrote {args.csv}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
