"""Fig 3 — pinned vs OS-managed threads (interference).

Paper: multiple concurrent GEMM/elementwise executors achieve up to ~45%
higher FLOPS with threads pinned to cores vs OS-scheduled (migration +
co-location on one physical core), and >6x vs one op on all cores.

We replay the same experiment in the simulator: 8 concurrent executors x 8
cores each.  The OS-managed leg's slowdown comes from the *measured*
contention model (:mod:`repro.hwperf`) when a calibration store with an
``interference`` section is supplied (``calibration_store=`` argument or
the ``REPRO_CALIBRATION_STORE`` environment variable); otherwise it falls
back to the analytic ``interference_multiplier`` — the factor is then the
paper's measurement, and the benchmark verifies the engine-level
consequence.
"""
from __future__ import annotations

import os

from repro.core import KNL7250, Graph, OpNode, SimConfig, interference_multiplier, op_time, simulate
from .common import Row, check_band


def _independent_gemms(n: int) -> Graph:
    g = Graph(f"par_gemms_{n}")
    for i in range(n):
        g.add(OpNode(f"gemm{i}", kind="gemm", flops=2 * 64 * 512 * 512,
                     bytes_in=(64 * 512 + 512 * 512) * 4, bytes_out=64 * 512 * 4,
                     meta={"rows": 64}))
    return g


def _measured_contention(calibration_store: str | None):
    """The measured ContentionModel from a calibration store's interference
    section, or None (missing path / no section / unreadable store)."""
    path = calibration_store or os.environ.get("REPRO_CALIBRATION_STORE")
    if not path or not os.path.exists(path):
        return None
    from repro.hwperf.model import ContentionModel
    from repro.runtime import CalibrationStore

    try:
        section = CalibrationStore(path).get_interference()
    except ValueError:
        return None
    return ContentionModel.from_dict(section) if section else None


def run(calibration_store: str | None = None) -> list[Row]:
    rows: list[Row] = []
    g = _independent_gemms(8)
    base = SimConfig(n_executors=8, team_size=8)
    pinned = simulate(g, KNL7250, base)
    contention = _measured_contention(calibration_store)
    if contention is not None:
        os_cfg = SimConfig(n_executors=8, team_size=8, contention=contention)
        source = "measured"
    else:
        os_cfg = SimConfig(
            n_executors=8, team_size=8,
            duration_multiplier=interference_multiplier(
                KNL7250, software_threads=64, pinned=False))
        source = "model:KNL"
    os_managed = simulate(g, KNL7250, os_cfg)
    gain = os_managed.makespan / pinned.makespan
    # the band only applies to the analytic leg: a measured model reports
    # whatever this machine's contention actually is (informational row)
    status = check_band(gain, 1.2, 1.7) if source == "model:KNL" else "INFO"
    rows.append(Row("fig3", "pinned_vs_os_flops_gain", gain, "x", source,
                    "paper: up to ~1.45x", status))

    # >6x claim: 8 pinned executors of 8 cores vs ONE op on all 64 cores
    one = g.nodes[0]
    concurrent_vs_single = 8 * op_time(KNL7250, one, 64) / pinned.makespan
    rows.append(Row("fig3", "concurrent8x8_vs_single_op_64c", concurrent_vs_single, "x",
                    "model:KNL", "paper: >6x", check_band(concurrent_vs_single, 6.0, 10.0)))
    return rows
