"""Fig 3 — pinned vs OS-managed threads (interference).

Paper: multiple concurrent GEMM/elementwise executors achieve up to ~45%
higher FLOPS with threads pinned to cores vs OS-scheduled (migration +
co-location on one physical core), and >6x vs one op on all cores.

We replay the same experiment in the simulator: 8 concurrent executors x 8
cores each, op durations multiplied by the calibrated interference factor
for the OS-managed case (``interference_multiplier(pinned=False)``) — the
factor itself is the paper's measurement, the benchmark verifies the
engine-level consequence.
"""
from __future__ import annotations

from repro.core import KNL7250, Graph, OpNode, SimConfig, interference_multiplier, op_time, simulate
from .common import Row, check_band


def _independent_gemms(n: int) -> Graph:
    g = Graph(f"par_gemms_{n}")
    for i in range(n):
        g.add(OpNode(f"gemm{i}", kind="gemm", flops=2 * 64 * 512 * 512,
                     bytes_in=(64 * 512 + 512 * 512) * 4, bytes_out=64 * 512 * 4,
                     meta={"rows": 64}))
    return g


def run() -> list[Row]:
    rows: list[Row] = []
    g = _independent_gemms(8)
    base = SimConfig(n_executors=8, team_size=8)
    pinned = simulate(g, KNL7250, base)
    os_managed = simulate(
        g, KNL7250,
        SimConfig(n_executors=8, team_size=8,
                  duration_multiplier=interference_multiplier(
                      KNL7250, software_threads=64, pinned=False)),
    )
    gain = os_managed.makespan / pinned.makespan
    rows.append(Row("fig3", "pinned_vs_os_flops_gain", gain, "x", "model:KNL",
                    "paper: up to ~1.45x", check_band(gain, 1.2, 1.7)))

    # >6x claim: 8 pinned executors of 8 cores vs ONE op on all 64 cores
    one = g.nodes[0]
    t_all_cores = op_time(KNL7250, one, 64)
    throughput_gain = (8 * t_all_cores) / pinned.makespan / (t_all_cores / t_all_cores)
    concurrent_vs_single = 8 * op_time(KNL7250, one, 64) / pinned.makespan
    rows.append(Row("fig3", "concurrent8x8_vs_single_op_64c", concurrent_vs_single, "x",
                    "model:KNL", "paper: >6x", check_band(concurrent_vs_single, 6.0, 10.0)))
    return rows
