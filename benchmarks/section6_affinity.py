"""§6 "Data cache locality" — reproducing the paper's NEGATIVE result.

The paper tried preferred-executor (cache-affinity) scheduling and found:
elementwise ops improved by a modest margin, matrix multiplications did not
(MKL's blocking spans L2 tiles), so the makespan barely moved and the idea
was dropped in favour of stream stores.

We replay the experiment: LSTM-medium under CPF with and without affinity,
elementwise ops modelled 8% faster when input-producer == executor, GEMMs
0% (the paper's observation is the *input*, the makespan is the *output*).
Expected: per-op elementwise time improves ~the modelled margin; makespan
gain stays under a few percent — confirming "not worth the restriction".
"""
from __future__ import annotations

from repro.core import KNL7250, SimConfig, simulate
from repro.models.paper_nets import paper_graph
from .common import Row, check_band


def run() -> list[Row]:
    rows: list[Row] = []
    g = paper_graph("lstm", "medium")
    base_cfg = dict(n_executors=8, team_size=8, policy="cpf")
    off = simulate(g, KNL7250, SimConfig(**base_cfg))
    on = simulate(g, KNL7250, SimConfig(**base_cfg, cache_affinity=True))

    def ew_time(res):
        return sum(e.end - e.start for e in res.trace
                   if g[e.op].kind == "elementwise")

    ew_gain = 1.0 - ew_time(on) / ew_time(off)
    mk_gain = 1.0 - on.makespan / off.makespan
    rows.append(Row("section6", "eltwise_optime_gain_with_affinity", ew_gain * 100, "%",
                    "model:KNL", "paper: 'modest margin'", check_band(ew_gain, 0.01, 0.10)))
    rows.append(Row("section6", "makespan_gain_with_affinity", mk_gain * 100, "%",
                    "model:KNL", "paper: makespan did not improve -> dropped",
                    check_band(mk_gain, -0.02, 0.04)))
    rows.append(Row("section6", "affinity_not_worth_it", float(mk_gain < 0.05), "bool",
                    "model:KNL", "paper's conclusion", "PASS" if mk_gain < 0.05 else "WARN"))
    return rows
