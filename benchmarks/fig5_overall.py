"""Fig 5 — Graphi vs TensorFlow batch training time (the headline table).

The TF-side gap decomposes into three *separately measured/calibrated*
factors, composed by the simulator:

1. scheduling: naive shared-queue policy (Table 2 isolates this);
2. interference: thread oversubscription (Eigen + OpenMP pools => ~2x
   software threads) x unpinned-migration penalty 1.45 (Fig 3's measured
   number) => ``interference_multiplier(software_threads=2*cores,
   pinned=False)``;
3. primitives: LIBXSMM-vs-MKL convolution factor for the conv nets
   (PathNet small-conv 1.6x, GoogleNet 1.3x — declared constants from the
   LIBXSMM paper's small-conv speedups; 1.0 for the GEMM-bound LSTMs).

Paper band: Graphi 2.1x-9.5x faster than TF across 4 nets x 3 sizes
(PathNet-large highest ~9.5x, GoogleNet ~3-4x, LSTM medium ~5x).
"""
from __future__ import annotations

from repro import api
from repro.core import KNL7250, SimConfig, interference_multiplier, simulate
from repro.models.paper_nets import PAPER_NETS, paper_graph
from .common import Row, check_band

PRIMITIVES = {"lstm": 1.0, "phased_lstm": 1.0, "pathnet": 2.0, "googlenet": 1.4}
PAPER = {  # approximate per-net Fig-5 speedup bands
    "lstm": (2.1, 7.0), "phased_lstm": (2.1, 7.0),
    "pathnet": (4.0, 9.5), "googlenet": (3.0, 4.0),
}


def run() -> list[Row]:
    rows: list[Row] = []
    tf_mult = interference_multiplier(KNL7250, software_threads=2 * KNL7250.n_workers,
                                      pinned=False)
    all_speedups = []
    for net in PAPER_NETS:
        for size in ("small", "medium", "large"):
            g = paper_graph(net, size)
            exe = api.compile(g, hw=KNL7250, backend="sim")
            n, k = exe.profile.best_config
            graphi = simulate(g, KNL7250, SimConfig(n_executors=n, team_size=k, policy="cpf"))
            # TF-like: same best parallelism (TF also runs ops concurrently),
            # naive policy + interference + primitive factor
            tf = simulate(g, KNL7250, SimConfig(
                n_executors=n, team_size=k, policy="random",
                duration_multiplier=tf_mult * PRIMITIVES[net], jitter=0.05,
            ))
            sp = tf.makespan / graphi.makespan
            all_speedups.append(sp)
            lo, hi = PAPER[net]
            rows.append(Row("fig5", f"{net}_{size}_graphi_vs_tf", sp, "x", "model:KNL",
                            f"paper ~{lo}-{hi}x", check_band(sp, lo, hi, slack=0.6)))
    rows.append(Row("fig5", "overall_band_min", min(all_speedups), "x", "model:KNL",
                    "paper overall 2.1x", check_band(min(all_speedups), 1.8, 5.0)))
    rows.append(Row("fig5", "overall_band_max", max(all_speedups), "x", "model:KNL",
                    "paper overall 9.5x", check_band(max(all_speedups), 5.0, 14.0)))
    return rows
