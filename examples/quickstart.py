"""Quickstart: the Graphi engine on a toy computation graph.

Builds a small diamond-shaped DAG of real jnp ops, profiles it, produces
the critical-path-first schedule, executes it with the host runtime
(centralized scheduler + per-executor buffers), and checks the result
against the sequential interpreter.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import KNL7250, Graph, GraphiEngine, OpNode, ascii_timeline


def build_graph() -> Graph:
    g = Graph("quickstart")
    D = 256
    g.add(OpNode("x", bytes_out=D * D * 4))               # input
    for i in range(4):                                    # 4 parallel branches
        g.add(OpNode(
            f"gemm{i}", kind="gemm", deps=("x",),
            flops=2 * D ** 3, bytes_in=2 * D * D * 4, bytes_out=D * D * 4,
            meta={"rows": D},
            fn=lambda a, i=i: jnp.tanh(a @ (a.T * (0.1 * (i + 1)))),
        ))
    g.add(OpNode(
        "combine", kind="elementwise", deps=tuple(f"gemm{i}" for i in range(4)),
        flops=4 * D * D, bytes_in=4 * D * D * 4, bytes_out=D * D * 4,
        fn=lambda *xs: sum(xs),
    ))
    g.add(OpNode(
        "loss", kind="elementwise", deps=("combine",),
        flops=D * D, bytes_in=D * D * 4, bytes_out=4,
        fn=lambda a: jnp.sum(a * a),
    ))
    return g


def main() -> None:
    g = build_graph()
    print(f"graph: {g}")

    engine = GraphiEngine(g, KNL7250)
    prof = engine.profile()
    print(f"profiler: best config = {prof.best_n_executors} executors "
          f"x {prof.best_team_size} cores, makespan {prof.best_makespan*1e6:.1f} us")

    sched = engine.schedule()
    print(f"CPF schedule (modelled):")
    print(ascii_timeline(
        [type("E", (), {"op": n, "executor": e, "start": s, "end": t})()
         for n, (e, s, t) in sched.placements.items()],
        sched.n_executors, width=72,
    ))

    x = jnp.asarray(np.random.default_rng(0).normal(size=(256, 256)), jnp.float32)
    host = engine.execute_host({"x": x})
    ref = g.execute({"x": x})
    err = float(jnp.abs(host.outputs["loss"] - ref["loss"]))
    print(f"host parallel run == sequential interpreter: err={err:.2e} "
          f"({'OK' if err < 1e-3 else 'MISMATCH'})")


if __name__ == "__main__":
    main()
