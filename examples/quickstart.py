"""Quickstart: ``repro.Runtime`` — any JAX function becomes a scheduled
Graphi graph on the process-wide runtime.

Builds the one :class:`repro.Runtime` a process needs (it owns the single
executor pool, the calibration store, and admission), compiles a plain JAX
function (four parallel GEMM branches + a combine) into an operator DAG,
inspects the profile / critical-path-first schedule, executes it with the
host runtime (the run leases its executors from the runtime), and checks
the result against calling the function directly.  Bare ``repro.compile``
does the same through ``repro.default_runtime()``.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

import repro
from repro.core import ascii_timeline


def f(x, w):
    """4 independent branches -> combine -> scalar loss (width-4 DAG)."""
    branches = [jnp.tanh(x @ (w * (0.1 * (i + 1)))) for i in range(4)]
    y = sum(branches)
    return jnp.sum(y * y)


def main() -> None:
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(256, 256)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(256, 256)), jnp.float32)

    rt = repro.Runtime(hw=repro.KNL7250)      # the process-wide session
    print(f"runtime: {rt.describe()}")
    exe = rt.compile(f, x, w)
    g = exe.graph
    print(f"captured: {g}")
    print(f"nodes: {g.names}")

    prof = exe.profile
    print(f"profiler: best config = {prof.best_n_executors} executors "
          f"x {prof.best_team_size} cores, makespan {prof.best_makespan*1e6:.1f} us")

    sched = exe.schedule
    print("CPF schedule (modelled):")
    print(ascii_timeline(
        [type("E", (), {"op": n, "executor": e, "start": s, "end": t})()
         for n, (e, s, t) in sched.placements.items()],
        sched.n_executors, width=72,
    ))
    cp_len, cp = exe.critical_path
    print(f"critical path ({cp_len*1e6:.1f} us): {' -> '.join(cp)}")

    out = exe(x, w)                       # host backend: leased parallel run
    ref = f(x, w)                         # uncompiled JAX
    err = float(jnp.abs(out - ref))
    used = len({e.executor for e in exe.last_run.trace})
    print(f"host parallel run == direct call: err={err:.2e} "
          f"({'OK' if err < 1e-3 else 'MISMATCH'}), {used} executors used "
          f"(leased from {rt.n_workers}-worker pool)")
    rt.close()


if __name__ == "__main__":
    main()
