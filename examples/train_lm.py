"""End-to-end driver: train a ~100M-parameter LM on the synthetic bigram
stream with the full production stack — sharded train step, microbatching,
checkpointing, fault-tolerant trainer, straggler watchdog.

Default run (~100M params, 200 steps) takes tens of minutes on this CPU;
``--tiny`` drops to a ~4M model for a 2-minute demonstration.  The loss
must descend from ~ln(V) toward the bigram entropy floor — that descent is
the acceptance check printed at the end.

    PYTHONPATH=src python examples/train_lm.py --tiny
    PYTHONPATH=src python examples/train_lm.py --steps 200    # ~100M params
"""
import argparse
import math

import jax

from repro.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig, ShapeSpec
from repro.data import DataConfig, SyntheticTokens
from repro.optim.adamw import AdamWConfig
from repro.train.step import (
    TrainStepConfig,
    compile_lm_loss,
    init_train_state,
    make_train_step,
)
from repro.train.trainer import Trainer, TrainerConfig


def model_100m() -> ModelConfig:
    return ModelConfig(
        name="lm-100m", family="dense", n_layers=10, d_model=640,
        n_heads=10, n_kv_heads=5, d_ff=2560, vocab_size=32_000,
        act="silu", scan_layers=True,
    )


def model_tiny() -> ModelConfig:
    return ModelConfig(
        name="lm-tiny", family="dense", n_layers=4, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=512, vocab_size=2_048,
        act="silu", scan_layers=True,
    )


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--tiny", action="store_true")
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--ckpt-dir", default="/tmp/train_lm_ckpt")
    args = p.parse_args()

    cfg = model_tiny() if args.tiny else model_100m()
    n_params = cfg.n_params()
    print(f"model: {cfg.name}, {n_params/1e6:.1f}M params")

    tcfg = TrainStepConfig(
        microbatches=2, remat=True,
        adamw=AdamWConfig(lr=1e-3),
        warmup_steps=max(1, args.steps // 10), total_steps=args.steps,
    )
    state = init_train_state(cfg, jax.random.key(0), tcfg.adamw)
    step = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))

    # Graphi view of the same loss through the process Runtime: capture ->
    # profile -> CPF schedule gives the modelled per-step makespan the
    # trainer reports next to wall-clock (one session also means one
    # executor pool / calibration store if a serve engine shares the process)
    import repro
    runtime = repro.default_runtime()
    shape = ShapeSpec("train_lm", args.seq, args.batch, "train")
    exe = compile_lm_loss(cfg, shape, backend="sim", runtime=runtime)
    ms = exe.schedule.makespan
    print(f"graphi: loss graph {len(exe.graph)} nodes, width {exe.graph.width()}, "
          f"{exe.schedule.n_executors}x{exe.schedule.team_size} executors, "
          f"scheduled makespan {ms*1e3:.2f} ms (model: {exe.hw.name})")

    data = SyntheticTokens(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch,
        kind="bigram", bigram_noise=0.15,
    ))
    trainer = Trainer(
        step, state, data.batch,
        TrainerConfig(total_steps=args.steps,
                      checkpoint_every=max(20, args.steps // 4),
                      log_every=max(5, args.steps // 20)),
        checkpoint=CheckpointManager(args.ckpt_dir, keep=2),
        scheduled_makespan=ms,
    )
    report = trainer.run()

    first = next(r["loss"] for r in report.history if "loss" in r)
    last = report.final_loss
    # bigram with noise eps over vocab V: H = (1-eps)ln(1/(1-eps)) ~ floor
    print("\nstep      loss    ms/step")
    for r in report.history:
        if "loss" in r:
            print(f"{r['step']:5d}  {r['loss']:8.4f}  {r['time_s']*1e3:8.0f}")
    print(f"\nuniform baseline ln(V) = {math.log(cfg.vocab_size):.3f}")
    print(f"loss {first:.3f} -> {last:.3f}  "
          f"({'DESCENDED OK' if last < first - 0.5 else 'NO DESCENT — check setup'})")
    print(f"restarts={report.restarts} stragglers={len(report.stragglers)}")


if __name__ == "__main__":
    main()
