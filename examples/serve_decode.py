"""Batched serving demo: wave-batching engine over a reduced gemma config.

Submits a mixed bag of requests (different prompt lengths and budgets),
serves them in waves, and reports per-wave batching plus decode throughput.

    PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models import transformer
from repro.serve.engine import Request, ServeConfig, ServeEngine


def main() -> None:
    cfg = get_config("gemma-2b", smoke=True)
    params = transformer.init_params(cfg, jax.random.key(7))
    engine = ServeEngine(cfg, params, ServeConfig(max_batch=4, max_len=128,
                                                  temperature=0.8))
    rng = np.random.default_rng(1)
    for i in range(10):
        plen = int(rng.integers(8, 48))
        engine.submit(Request(
            request_id=i,
            prompt=rng.integers(1, cfg.vocab_size, size=plen).astype(np.int32),
            max_new_tokens=int(rng.integers(8, 32)),
            eos_id=None,
        ))
    t0 = time.perf_counter()
    done = engine.run()
    dt = time.perf_counter() - t0
    total = sum(len(r.output) for r in done)
    print(f"{len(done)} requests -> {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s incl. compile)")
    for r in done:
        print(f"  req {r.request_id:2d}: prompt {len(r.prompt):3d} tok, "
              f"generated {len(r.output):3d}, head={r.output[:6]}")


if __name__ == "__main__":
    main()
