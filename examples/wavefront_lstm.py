"""The flagship scheduling demo (paper §7.4): critical-path-first scheduling
automatically recovers cuDNN's hand-crafted diagonal-wavefront LSTM
schedule.  The recovered schedule is then frozen into the static stacked
plan (DESIGN.md §2.1) and validated numerically against the sequential
interpreter.

Note on the timing below: the stacked plan trades (L+T-1)/T extra stacked
cell invocations for L-way *spatial* parallelism — on one CPU core there is
no parallelism to win, so sequential is faster here; the win appears when
the leading L axis is sharded over executor groups (see
benchmarks/tpu_slot_stacking.py for the pod-model account).

    PYTHONPATH=src python examples/wavefront_lstm.py
"""
import time

import jax
import jax.numpy as jnp

from repro import api as graphi
from repro.core import (
    TPUV5E,
    ascii_timeline,
    diagonals,
    is_wavefront_order,
    recurrence_graph,
    sequential_lstm,
    stacked_wavefront_lstm,
)

L, T, B, H = 4, 12, 16, 128


def main() -> None:
    flops = 2 * 2 * B * H * 4 * H
    g = recurrence_graph(L, T, flops_per_cell=flops, bytes_per_cell=3 * B * H * 4)
    print(f"recurrence DAG: {L} layers x {T} steps, width={g.width()}")

    exe = graphi.compile(g, hw=TPUV5E, backend="sim", n_workers=L, reserved_workers=0)
    exe.profile_with(extra_configs=[(L, 1)])
    sched = exe.schedule
    order = sched.start_order()
    ok = is_wavefront_order(order, g)
    print(f"CPF start order follows anti-diagonals: {ok}")
    print(f"reference diagonals: {[len(d) for d in diagonals(L, T)]} cells/wave")
    print(ascii_timeline(
        [type("E", (), {"op": n, "executor": e, "start": s, "end": t})()
         for n, (e, s, t) in sched.placements.items()],
        sched.n_executors, width=76,
    ))

    # the same plan as real compute: stacked diagonal cells vs lax.scan
    ks = jax.random.split(jax.random.key(0), 4)
    stacked = {
        "Wx": jax.random.normal(ks[0], (L, H, 4 * H)) * 0.05,
        "Wh": jax.random.normal(ks[1], (L, H, 4 * H)) * 0.05,
        "b": jax.random.normal(ks[2], (L, 4 * H)) * 0.05,
    }
    xs = jax.random.normal(ks[3], (T, B, H))
    per_layer = [jax.tree.map(lambda p, i=i: p[i], stacked) for i in range(L)]

    seq_fn = jax.jit(lambda ps, xs: sequential_lstm([jax.tree.map(lambda q, i=i: q[i], ps) for i in range(L)], xs))
    wav_fn = jax.jit(stacked_wavefront_lstm, static_argnums=2)
    ref = seq_fn(stacked, xs).block_until_ready()
    out = wav_fn(stacked, xs, L).block_until_ready()
    err = float(jnp.abs(out - ref).max())
    print(f"stacked wavefront == sequential: max err {err:.2e}")

    for name, fn, args in (("sequential", seq_fn, (stacked, xs)),
                           ("wavefront", wav_fn, (stacked, xs, L))):
        t0 = time.perf_counter()
        for _ in range(10):
            fn(*args).block_until_ready()
        print(f"{name:11s}: {(time.perf_counter()-t0)/10*1e3:7.2f} ms/iter "
              f"[measured, 1-CPU — stacked wins only with the L axis sharded]")


if __name__ == "__main__":
    main()
